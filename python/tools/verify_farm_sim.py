"""Algorithm-level verification of the farm PR's scheduling logic, ported 1:1.

1. coordinator::faults — spec grammar (accept + reject sets), kill
   permanence, stall one-shot, derate composition, first-fault-wins
   precedence, seeded determinism, unconditional-draw stream alignment
   (non-probabilistic kinds consume no draws; probabilistic kinds draw
   every call), empirical fail/spike rates vs their configured p.
2. coordinator::batcher — EDF insertion order vs a reference sort key
   (fuzzed), FIFO completion fairness under splits (fuzzed), linger
   threshold monotone in the clock, requeue position + admission
   bypass, purge of a split head, back-pressure, cap clamping.
3. Farm retry arithmetic — dispatches = 1 + max_retries exactly,
   exponential backoff series base*2^(attempt-1), shift capped at 16.
4. Discrete-tick policy model composed from the ported pieces
   (expire -> promote -> probe -> dispatch, the supervisor's pass
   order): fuzzed fault schedules over 1-3 chips; every request
   resolves exactly once and no later than its (defaulted) deadline,
   image conservation holds every tick, fault-free farms serve
   everything Ok, all-dead farms never hang, bulk sheds before
   interactive on a degraded farm, and identical seeds reproduce
   identical outcome schedules.

The model simulates the *policy* (the threading/mpsc layer is exercised
by rust/tests/farm_chaos.rs); its arithmetic — EDF order, effective cap
div_ceil(device_batch*live, chips), attempt bookkeeping, quarantine and
probe timing — mirrors coordinator::farm line for line.
"""

M64 = (1 << 64) - 1


def splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & M64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return state, z ^ (z >> 31)


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


class Rng:
    def __init__(self, seed):
        st = seed & M64
        self.s = []
        for _ in range(4):
            st, v = splitmix64(st)
            self.s.append(v)

    def next_u64(self):
        s = self.s
        result = (rotl((s[0] + s[3]) & M64, 23) + s[0]) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]; s[3] ^= s[1]; s[1] ^= s[2]; s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def uniform(self):
        return float(self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        assert n > 0
        x = self.next_u64()
        m = x * n
        lo = m & M64
        if lo < n:
            t = ((1 << 64) - n) % n
            while lo < t:
                x = self.next_u64()
                m = x * n
                lo = m & M64
        return m >> 64

    def fork(self, tag):
        return Rng(self.next_u64() ^ ((tag * 0x9E3779B97F4A7C15) & M64))


# --- coordinator::faults port ------------------------------------------------
# Kinds as tuples: ("kill", after), ("fail", p), ("stall", at, ms),
# ("derate", f), ("spike", p, ms).

def parse_ms(s):
    if s.endswith("ms"):
        s = s[:-2]
    if not s.isdigit():
        raise ValueError(f"bad millisecond value {s!r}")
    return int(s)


def parse_prob(s):
    p = float(s)  # raises on garbage, like f64::parse
    if not (0.0 <= p <= 1.0):
        raise ValueError(f"probability {p} outside [0, 1]")
    return p


def parse_kind(s):
    if s.startswith("kill"):
        rest = s[4:]
        if rest == "":
            return ("kill", 0)
        if rest.startswith("@") and rest[1:].isdigit():
            return ("kill", int(rest[1:]))
        raise ValueError(f"kill takes '@<call>' (got {s!r})")
    if s.startswith("fail:"):
        return ("fail", parse_prob(s[5:]))
    if s.startswith("stall@"):
        call_s, _, ms_s = s[6:].partition(":")
        if not _:
            raise ValueError(f"stall takes '@<call>:<ms>' (got {s!r})")
        if not call_s.isdigit():
            raise ValueError(f"bad stall call index {call_s!r}")
        return ("stall", int(call_s), parse_ms(ms_s))
    if s.startswith("derate:"):
        factor = float(s[7:])
        if factor < 1.0:
            raise ValueError(f"derate factor must be >= 1.0, got {factor}")
        return ("derate", factor)
    if s.startswith("spike:"):
        p_s, _, ms_s = s[6:].partition(":")
        if not _:
            raise ValueError(f"spike takes ':<prob>:<ms>' (got {s!r})")
        return ("spike", parse_prob(p_s), parse_ms(ms_s))
    raise ValueError(f"unknown fault kind {s!r}")


def parse_plan(spec):
    per_chip, all_kinds = [], []
    for entry in (e.strip() for e in spec.split(",")):
        if not entry:
            continue
        if "=" not in entry:
            raise ValueError(f"fault entry {entry!r}: expected <target>=<kind>")
        target, kind_s = entry.split("=", 1)
        kind = parse_kind(kind_s.strip())
        target = target.strip()
        if target == "all":
            all_kinds.append(kind)
        elif target.startswith("chip") and target[4:].isdigit():
            per_chip.append((int(target[4:]), kind))
        else:
            raise ValueError(f"fault target {target!r}: expected chip<N> or all")
    return per_chip, all_kinds


def kinds_for(plan, chip):
    per_chip, all_kinds = plan
    return list(all_kinds) + [k for (c, k) in per_chip if c == chip]


def derate_factor(plan, chip):
    f = 1.0
    for k in kinds_for(plan, chip):
        if k[0] == "derate":
            f *= max(k[1], 1.0)
    return f


class ChipFaults:
    """Port of ChipFaults::before_call — one unconditional uniform per
    probabilistic fault, every call, so the schedule depends only on the
    call index."""

    def __init__(self, kinds, rng):
        self.kinds = kinds
        self.rng = rng
        self.calls = 0
        self.injected_failures = 0
        self.injected_delays = 0

    def before_call(self):
        call = self.calls
        self.calls += 1
        sleep, derate, fail = 0, 1.0, None
        for k in self.kinds:
            if k[0] == "kill":
                if call >= k[1] and fail is None:
                    fail = f"chip dead (killed at call {k[1]})"
            elif k[0] == "fail":
                u = self.rng.uniform()
                if u < k[1] and fail is None:
                    fail = f"injected fault (p={k[1]})"
            elif k[0] == "stall":
                if call == k[1]:
                    sleep += k[2]
            elif k[0] == "derate":
                derate *= max(k[1], 1.0)
            elif k[0] == "spike":
                u = self.rng.uniform()
                if u < k[1]:
                    sleep += k[2]
        if fail is not None:
            self.injected_failures += 1
        if sleep > 0:
            self.injected_delays += 1
        return sleep, derate, fail


def chip_faults(plan, chip, base_seed):
    return ChipFaults(kinds_for(plan, chip), Rng(base_seed).fork(0xFA017000 + chip))


# --- 1. faults: grammar, precedence, determinism, rates ----------------------
plan = parse_plan(
    "chip0=kill@3, chip1=fail:0.5, chip2=stall@2:200ms, chip3=derate:4, "
    "chip4=spike:0.3:50, all=fail:0.1"
)
assert kinds_for(plan, 0) == [("fail", 0.1), ("kill", 3)]
assert kinds_for(plan, 2) == [("fail", 0.1), ("stall", 2, 200)]
assert kinds_for(plan, 7) == [("fail", 0.1)]
assert derate_factor(plan, 3) == 4.0 and derate_factor(plan, 0) == 1.0
for bad in ["chip0", "chipX=kill", "chip0=explode", "chip0=fail:1.5",
            "chip0=derate:0.5", "chip0=stall@1", "chip0=spike:0.5"]:
    try:
        parse_plan(bad)
        raise AssertionError(f"accepted {bad!r}")
    except ValueError:
        pass
assert parse_plan("") == ([], []) and parse_plan("  ") == ([], [])

f = chip_faults(parse_plan("chip0=kill@2"), 0, 7)
assert f.before_call()[2] is None and f.before_call()[2] is None
for _ in range(10):
    assert f.before_call()[2] is not None
assert f.calls == 12 and f.injected_failures == 10

f = chip_faults(parse_plan("chip1=stall@1:30"), 1, 7)
assert [f.before_call()[0] for _ in range(3)] == [0, 30, 0]
assert f.injected_delays == 1

f = chip_faults(parse_plan("chip0=derate:2,chip0=derate:3,chip0=kill@0"), 0, 0)
sleep, derate, fail = f.before_call()
assert derate == 6.0 and "killed" in fail  # kill listed first wins the message

def run(seed, chip=0):
    f = chip_faults(parse_plan("all=fail:0.5"), chip, seed)
    return [f.before_call()[2] is not None for _ in range(64)]


assert run(1) == run(1) and run(1) != run(2)
assert run(1, chip=0) != run(1, chip=1)
hits = sum(run(1))
assert 10 <= hits <= 54, hits

# Stream alignment: kill/stall/derate consume no draws, so composing them
# with fail:p leaves the RNG stream — hence the fail schedule — unchanged.
fa = ChipFaults([("kill", 5), ("stall", 3, 10), ("derate", 2.0), ("fail", 0.5)], Rng(99))
fb = ChipFaults([("fail", 0.5)], Rng(99))
for _ in range(200):
    fa.before_call()
    fb.before_call()
assert fa.rng.s == fb.rng.s, "non-probabilistic kinds must not consume draws"

# Empirical rates track the configured probabilities.
f = chip_faults(parse_plan("chip0=fail:0.3"), 0, 11)
N = 20000
fails = sum(f.before_call()[2] is not None for _ in range(N))
assert abs(fails / N - 0.3) < 0.015, fails / N
f = chip_faults(parse_plan("chip0=spike:0.2:5"), 0, 12)
spikes = sum(f.before_call()[0] > 0 for _ in range(N))
assert abs(spikes / N - 0.2) < 0.015, spikes / N
print(f"1. faults: grammar + precedence + alignment ok; rates {fails/N:.3f}/0.3, "
      f"{spikes/N:.3f}/0.2")


# --- coordinator::batcher port ----------------------------------------------
class Request:
    __slots__ = ("id", "n_images", "arrived", "deadline", "priority", "attempt")

    def __init__(self, id, n_images, arrived, deadline=None, priority=1, attempt=0):
        self.id = id
        self.n_images = n_images
        self.arrived = arrived
        self.deadline = deadline
        self.priority = priority
        self.attempt = attempt


def before(a, b):
    if a.deadline is not None and b.deadline is not None and a.deadline != b.deadline:
        return a.deadline < b.deadline
    if (a.deadline is not None) != (b.deadline is not None):
        return a.deadline is not None
    return (a.arrived, a.id) < (b.arrived, b.id)


class Batcher:
    def __init__(self, device_batch, linger, max_queue):
        self.device_batch = device_batch
        self.linger = linger
        self.max_queue = max_queue
        self.queue = []
        self.head_remaining = None

    def queue_len(self):
        return len(self.queue) + (self.head_remaining is not None)

    def queued_images(self):
        head = self.head_remaining.n_images if self.head_remaining is not None else 0
        return head + sum(r.n_images for r in self.queue)

    def insert_ordered(self, req):
        for i, q in enumerate(self.queue):
            if before(req, q):
                self.queue.insert(i, req)
                return
        self.queue.append(req)

    def push(self, req):
        if self.queue_len() >= self.max_queue:
            return False
        self.insert_ordered(req)
        return True

    def requeue(self, reqs):
        for r in reqs:
            self.insert_ordered(r)

    def purge(self, expired):
        dropped = []
        if self.head_remaining is not None and expired(self.head_remaining):
            dropped.append(self.head_remaining)
            self.head_remaining = None
        kept = []
        for r in self.queue:
            (dropped if expired(r) else kept).append(r)
        self.queue = kept
        return dropped

    def oldest_wait(self, now):
        if self.head_remaining is not None:
            return max(now - self.head_remaining.arrived, 0)
        if self.queue:
            return max(now - self.queue[0].arrived, 0)
        return None

    def next_batch_with(self, now, cap):
        cap = min(max(cap, 1), self.device_batch)
        if self.queued_images() == 0:
            return None
        w = self.oldest_wait(now)
        lingered = w is not None and w >= self.linger
        if self.queued_images() < cap and not lingered:
            return None
        parts, total = [], 0
        if self.head_remaining is not None:
            head, self.head_remaining = self.head_remaining, None
            take = min(head.n_images, cap)
            parts.append((head.id, take))
            total += take
            if take < head.n_images:
                head.n_images -= take
                self.head_remaining = head
        while total < cap and self.queue:
            req = self.queue.pop(0)
            take = min(req.n_images, cap - total)
            parts.append((req.id, take))
            total += take
            if take < req.n_images:
                req.n_images -= take
                self.head_remaining = req
                break
        return parts, total


# --- 2. batcher: EDF order, fairness, linger, requeue, purge -----------------
def ref_key(r):
    return (r.deadline is None, r.deadline if r.deadline is not None else 0,
            r.arrived, r.id)


rng = Rng(21)
for trial in range(50):
    b = Batcher(8, 0, 1 << 30)
    reqs = []
    for rid in range(30):
        dl = None if rng.below(3) == 0 else rng.below(100)
        reqs.append(Request(rid, 1 + rng.below(4), rng.below(10), dl))
    for r in reqs:
        assert b.push(r)
    got = [r.id for r in b.queue]
    want = [r.id for r in sorted(reqs, key=ref_key)]
    assert got == want, f"trial {trial}: EDF order {got} != {want}"

rng = Rng(11)
for trial in range(20):
    cap = 1 + rng.below(8)
    b = Batcher(cap, 0, 1 << 30)
    n_reqs = 2 + rng.below(12)
    sizes = {}
    for rid in range(n_reqs):
        n = 1 + rng.below(3 * cap)
        sizes[rid] = n
        assert b.push(Request(rid, n, rid))  # strictly increasing arrivals
    completion, delivered = [], {}
    while True:
        got = b.next_batch_with(10**9, cap)
        if got is None:
            break
        parts, total = got
        assert total <= cap
        for rid, count in parts:
            delivered[rid] = delivered.get(rid, 0) + count
            assert delivered[rid] <= sizes[rid]
            if delivered[rid] == sizes[rid]:
                completion.append(rid)
    assert completion == list(range(n_reqs)), f"trial {trial}: unfair {completion}"

for offset in [0, 3, 9, 10, 11, 50]:
    b = Batcher(8, 10, 16)
    b.push(Request(1, 2, 0))
    assert (b.next_batch_with(offset, 8) is not None) == (offset >= 10), offset

b = Batcher(8, 0, 16)
b.push(Request(1, 4, 0))
b.push(Request(2, 4, 1))
parts, _ = b.next_batch_with(0, 8)
assert parts == [(1, 4), (2, 4)]
b.push(Request(3, 4, 2))
b.requeue(Request(rid, n, rid - 1, attempt=1) for rid, n in parts)
order = [b.next_batch_with(10**9, 4)[0] for _ in range(3)]
assert order == [[(1, 4)], [(2, 4)], [(3, 4)]], order

b = Batcher(4, 0, 1)
assert b.push(Request(1, 4, 0)) and not b.push(Request(2, 1, 0))
parts, _ = b.next_batch_with(0, 4)
b.requeue(Request(rid, n, 0) for rid, n in parts)
b.requeue([Request(9, 1, 1)])  # at the cap: requeue still lands
assert b.queue_len() == 2 and b.next_batch_with(0, 4)[0][0][0] == 1

b = Batcher(8, 0, 16)
b.push(Request(1, 6, 0))
b.push(Request(2, 2, 0))
assert b.next_batch_with(0, 2)[0] == [(1, 2)]
dropped = b.purge(lambda r: r.id == 1)
assert len(dropped) == 1 and dropped[0].n_images == 4  # the split head
assert b.queue_len() == 1 and b.next_batch_with(1, 8)[0] == [(2, 2)]
assert b.next_batch_with(0, 100) is None  # cap clamps to device_batch; empty
print("2. batcher: EDF vs reference sort (50 fuzz), FIFO fairness (20 fuzz), "
      "linger monotone, requeue/purge/back-pressure ok")


# --- 3. retry/backoff arithmetic ---------------------------------------------
def retry_trace(max_retries, base):
    """Dispatch attempt bookkeeping, as coordinator::farm does it:
    dispatch sets attempt = max(attempt, 1); on failure, attempt >
    max_retries resolves Failed, else backoff = base * 2^(attempt-1)
    (shift capped at 16) and attempt += 1."""
    attempt, dispatches, backoffs = 0, 0, []
    while True:
        attempt = max(attempt, 1)
        dispatches += 1
        if attempt > max_retries:
            return dispatches, backoffs
        backoffs.append(base * (1 << min(attempt - 1, 16)))
        attempt += 1


for mr in range(5):
    d, bo = retry_trace(mr, 10)
    assert d == 1 + mr, (mr, d)
    assert bo == [10 * (1 << i) for i in range(mr)], bo
_, bo = retry_trace(40, 1)
assert bo[-1] == 1 << 16 and bo[20] == 1 << 16, "shift must cap at 16"
print("3. retries: dispatches = 1+max_retries for mr in 0..4, backoff "
      "series doubles, shift caps at 2^16")


# --- 4. discrete-tick policy model -------------------------------------------
class FarmModel:
    """The supervisor's pass order (expire -> promote -> probe ->
    dispatch) over the ported batcher/faults/retry arithmetic. Chips
    execute instantaneously; one tick = one supervisor wakeup."""

    def __init__(self, n_chips, plan, base_seed, device_batch=4, linger=1,
                 max_retries=2, backoff_base=1, quarantine=5, default_deadline=200):
        self.device_batch = device_batch
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.quarantine = quarantine
        self.default_deadline = default_deadline
        self.batcher = Batcher(device_batch, linger, 1 << 30)
        self.chips = [{"faults": chip_faults(plan, c, base_seed),
                       "state": "idle", "until": 0} for c in range(n_chips)]
        self.pending_retry = []  # (ready_at, Request part)
        self.reqs = {}           # id -> canonical Request
        self.delivered = {}
        self.resolved = {}       # id -> (tick, outcome)
        self.shed = 0

    def live(self):
        return sum(c["state"] == "idle" for c in self.chips)

    def resolve(self, rid, t, outcome):
        assert rid not in self.resolved, f"double resolution of {rid}"
        self.resolved[rid] = (t, outcome)

    def submit(self, t, rid, n, deadline, priority):
        dl = deadline if deadline is not None else t + self.default_deadline
        self.reqs[rid] = Request(rid, n, t, dl, priority)
        live = self.live()
        if (live < len(self.chips) and priority == 0
                and self.batcher.queued_images() >= max(live, 1) * self.device_batch):
            self.shed += 1
            self.resolve(rid, t, "rejected")
            return
        self.batcher.push(Request(rid, n, t, dl, priority))

    def requeue_failed(self, t, rid, count):
        r = self.reqs[rid]
        if r.attempt > self.max_retries:
            self.resolve(rid, t, "failed")
            return
        a = r.attempt
        r.attempt += 1
        part = Request(rid, count, r.arrived, r.deadline, r.priority, r.attempt)
        bo = self.backoff_base * (1 << min(a - 1, 16))
        if bo == 0:
            self.batcher.requeue([part])
        else:
            self.pending_retry.append((t + bo, part))

    def tick(self, t):
        expired = [rid for rid, r in self.reqs.items()
                   if rid not in self.resolved and r.deadline <= t]
        for rid in expired:
            self.resolve(rid, t, "deadline")
        ready = [r for at, r in self.pending_retry if at <= t]
        self.pending_retry = [(at, r) for at, r in self.pending_retry if at > t]
        self.batcher.requeue(ready)
        for c in self.chips:
            if c["state"] == "quarantined" and c["until"] <= t:
                if c["faults"].before_call()[2] is None:
                    c["state"] = "idle"
                else:
                    c["until"] = t + self.quarantine
        while True:
            idle = [i for i, c in enumerate(self.chips) if c["state"] == "idle"]
            if not idle:
                break
            cap = -(-self.device_batch * len(idle) // len(self.chips))
            got = self.batcher.next_batch_with(t, cap)
            if got is None:
                break
            parts, _ = got
            chip = self.chips[idle[0]]
            fail = chip["faults"].before_call()[2]
            for rid, count in parts:
                if rid in self.resolved:
                    continue
                self.reqs[rid].attempt = max(self.reqs[rid].attempt, 1)
                if fail is not None:
                    self.requeue_failed(t, rid, count)
                else:
                    self.delivered[rid] = self.delivered.get(rid, 0) + count
                    if self.delivered[rid] >= self.reqs[rid].n_images:
                        self.resolve(rid, t, "ok")
            if fail is not None:
                chip["state"] = "quarantined"
                chip["until"] = t + self.quarantine
        done = set(self.resolved)
        self.batcher.purge(lambda r: r.id in done)
        self.pending_retry = [(at, r) for at, r in self.pending_retry
                              if r.id not in done]
        # Image conservation: nothing admitted is ever silently dropped.
        queued = {}
        if self.batcher.head_remaining is not None:
            h = self.batcher.head_remaining
            queued[h.id] = queued.get(h.id, 0) + h.n_images
        for r in self.batcher.queue:
            queued[r.id] = queued.get(r.id, 0) + r.n_images
        for _, r in self.pending_retry:
            queued[r.id] = queued.get(r.id, 0) + r.n_images
        for rid, r in self.reqs.items():
            if rid not in self.resolved:
                have = self.delivered.get(rid, 0) + queued.get(rid, 0)
                assert have == r.n_images, f"req {rid}: {have} != {r.n_images}"


def run_scenario(seed):
    r = Rng(seed)
    n_chips = 1 + r.below(3)
    entries = []
    for c in range(n_chips):
        roll = r.below(5)
        if roll == 1:
            entries.append(f"chip{c}=kill@{r.below(6)}")
        elif roll == 2:
            entries.append(f"chip{c}=fail:0.{1 + r.below(9)}")
        elif roll == 3:
            entries.append(f"chip{c}=stall@{r.below(4)}:3,chip{c}=fail:0.2")
        elif roll == 4:
            entries.append(f"chip{c}=derate:2,chip{c}=spike:0.3:2")
    plan = parse_plan(",".join(entries))
    m = FarmModel(n_chips, plan, base_seed=seed, max_retries=r.below(4),
                  backoff_base=r.below(3))
    subs = []
    for rid in range(3 + r.below(10)):
        at = r.below(20)
        dl = None if r.below(3) == 0 else at + 5 + r.below(60)
        subs.append((at, rid, 1 + r.below(6), dl, r.below(2)))
    for t in range(260):
        for at, rid, n, dl, pr in subs:
            if at == t:
                m.submit(t, rid, n, dl, pr)
        m.tick(t)
    for at, rid, n, dl, pr in subs:
        assert rid in m.resolved, f"seed {seed}: request {rid} hung"
        tick, outcome = m.resolved[rid]
        assert tick <= m.reqs[rid].deadline, f"seed {seed}: {rid} past deadline"
    return {rid: m.resolved[rid] for _, rid, _, _, _ in subs}


for seed in range(40):
    a, b = run_scenario(seed), run_scenario(seed)
    assert a == b, f"seed {seed}: not reproducible"

# Fault-free farm: everything (including priority 0) serves Ok.
m = FarmModel(2, parse_plan(""), base_seed=1)
for rid in range(8):
    m.submit(0, rid, 1 + rid % 5, None, rid % 2)
for t in range(40):
    m.tick(t)
assert all(m.resolved[rid][1] == "ok" for rid in range(8)), m.resolved

# All-dead farm: every request resolves to a typed error, none hang.
m = FarmModel(2, parse_plan("all=kill@0"), base_seed=1)
for rid in range(6):
    m.submit(0, rid, 2, None, 1)
for t in range(260):
    m.tick(t)
outcomes = {m.resolved[rid][1] for rid in range(6)}
assert len(m.resolved) == 6 and "ok" not in outcomes, m.resolved

# Degraded farm sheds bulk (priority 0) but never interactive (priority 1).
m = FarmModel(2, parse_plan("all=kill@0"), base_seed=1)
for rid in range(4):
    m.submit(0, rid, 1, None, 1)  # seed work to saturate the dead farm
for t in range(3):
    m.tick(t)
for rid in range(4, 10):
    m.submit(3, rid, 1, None, 0)  # bulk: shed
for rid in range(10, 12):
    m.submit(3, rid, 1, None, 1)  # interactive: admitted
for t in range(3, 260):
    m.tick(t)
assert len(m.resolved) == 12 and m.shed >= 1
bulk = [m.resolved[rid][1] for rid in range(4, 10)]
assert "rejected" in bulk and "ok" not in bulk, bulk
assert all(m.resolved[rid][1] != "rejected" for rid in range(10, 12))
print("4. policy model: 40 fuzzed schedules resolve exactly once by deadline "
      "(reproducibly), conservation holds, fault-free => all ok, all-dead => "
      "typed errors, bulk sheds before interactive")

print("ALL FARM CHECKS PASSED")

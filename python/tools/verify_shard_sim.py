#!/usr/bin/env python3
"""Algorithm-level verification of the intra-chain sharded engine (PR 9).

The dev container has no Rust toolchain, so this ports the sharding-specific
logic of `gibbs::engine` 1:1 to Python (stdlib only) and checks the
properties the Rust tests assert with `cargo`:

  1. SIMD padding algebra: `SweepPlan::from_topo` pads every node's
     gathered (weight, neighbor) list to a LANE=8 multiple with
     zero-weight sentinels and `half()` accumulates the lane products in
     order. In f32 arithmetic (every operation rounded through a 4-byte
     struct) the chunked ordered sum equals the unpadded sequential sum
     for every input: a zero-weight product is +/-0.0 and x + (+/-0.0) == x
     for all finite x (the lone exception, -0.0 + 0.0 = +0.0, changes the
     sign bit of a zero field only, and sigmoid(+0.0) == sigmoid(-0.0), so
     the sampled spin distribution is untouched);
  2. shard partition: a port of `shard_block_bounds` — the block offsets
     cover the update list, ascend strictly, respect MAX_SHARD_BLOCKS,
     stay near the target size, and every interior boundary is
     word-aligned in the color-major packed bit layout (so the packed
     sharded twin never has two shards read-modify-writing one u64); and
     the contiguous assignment shard = blk*S//nb covers all blocks in
     order at every width S — blocks (and their RNG streams) exist
     independently of S by construction;
  3. sharded chromatic Gibbs: a toy bipartite machine driven block by
     block on per-(color, block) deterministic hash streams reaches a
     bit-identical state whether the blocks of a color run sequentially,
     grouped into any shard width, or in any shard execution order —
     within a color phase, blocks write disjoint nodes and read only the
     opposite color, so block executions commute (the race-freedom
     argument `run_chain_sharded` rests on), clamped or free.

Run: python3 python/tools/verify_shard_sim.py -> ALL SHARD CHECKS PASSED
"""

import math
import random
import struct

LANE = 8
MAX_SHARD_BLOCKS = 64


def f32(x):
    """Round to nearest f32 — every arithmetic op goes through this."""
    return struct.unpack("f", struct.pack("f", x))[0]


def f32_bits(x):
    return struct.pack("f", x)


# ------------------------------------------------- 1. padding algebra --


def seq_sum(bias, pairs):
    acc = f32(bias)
    for w, s in pairs:
        acc = f32(acc + f32(w * s))
    return acc


def chunked_padded_sum(bias, pairs, spins):
    """half()'s loop: pad to a LANE multiple with (0.0, nbr=0) sentinels,
    form the lane products of each chunk, then fold them in order."""
    padded = list(pairs)
    while len(padded) % LANE != 0:
        padded.append((0.0, spins[0]))  # sentinel reads a live spin
    acc = f32(bias)
    for base in range(0, len(padded), LANE):
        prod = [f32(w * s) for w, s in padded[base : base + LANE]]
        for p in prod:
            acc = f32(acc + p)
    return acc


def check_padding_algebra():
    rng = random.Random(11)
    cases = 0
    for trial in range(500):
        deg = rng.randrange(0, 41)  # includes 0 (isolated node) and odd degrees
        spins = [rng.choice([-1.0, 1.0]) for _ in range(8)]
        pairs = [
            (f32(rng.uniform(-2.0, 2.0)), rng.choice([-1.0, 1.0])) for _ in range(deg)
        ]
        bias = f32(rng.uniform(-3.0, 3.0))
        a = seq_sum(bias, pairs)
        b = chunked_padded_sum(bias, pairs, spins)
        assert a == b, f"trial {trial}: chunked {b!r} != sequential {a!r}"
        # Bitwise identical except possibly the sign of a zero.
        if a != 0.0:
            assert f32_bits(a) == f32_bits(b), f"trial {trial}: bit mismatch"
        cases += 1
    # The one tolerated exception, pinned: -0.0 + (+0.0 sentinel) = +0.0
    # flips only the sign bit of a zero, and both signs sigmoid to 0.5.
    assert f32(-0.0 + 0.0) == 0.0
    assert 1.0 / (1.0 + math.exp(-0.0)) == 1.0 / (1.0 + math.exp(0.0)) == 0.5
    print(f"  padding algebra: chunked LANE={LANE} sum == sequential sum "
          f"on {cases} random gather lists (bitwise, zero-sign caveat pinned)")


# ---------------------------------------------- graph + packed layout --


def build(grid, rules):
    """graph::build connection structure + checkerboard coloring."""
    n = grid * grid
    nbrs = [[] for _ in range(n)]
    for y in range(grid):
        for x in range(grid):
            u = y * grid + x
            for (a, b) in rules:
                for (dx, dy) in [(a, b), (-b, a), (-a, -b), (b, -a)]:
                    xx, yy = x + dx, y + dy
                    if 0 <= xx < grid and 0 <= yy < grid:
                        nbrs[u].append(yy * grid + xx)
    color = [((i % grid) + (i // grid)) % 2 for i in range(n)]
    return nbrs, color


G8 = [(0, 1), (4, 1)]
G12 = [(0, 1), (4, 1), (5, 2)]


def packed_bit_pos(color):
    """Color-major packed layout: color-0 nodes (ascending, clamped or
    not) hold bits 0.., color-1 starts at the next word boundary."""
    n = len(color)
    pos = [0] * n
    i0 = 0
    for i in range(n):
        if color[i] == 0:
            pos[i] = i0
            i0 += 1
    base = ((i0 + 63) // 64) * 64
    i1 = 0
    for i in range(n):
        if color[i] == 1:
            pos[i] = base + i1
            i1 += 1
    return pos


def shard_block_bounds(nodes, bit_pos):
    """1:1 port of gibbs::engine::shard_block_bounds."""
    ln = len(nodes)
    if ln == 0:
        return [0]
    target = max(-(-ln // MAX_SHARD_BLOCKS), 1)
    off = [0]
    prev = 0
    for j in range(1, ln):
        w = bit_pos[nodes[j]] // 64
        w_prev = bit_pos[nodes[j - 1]] // 64
        if j - prev >= target and w != w_prev:
            off.append(j)
            prev = j
    off.append(ln)
    return off


def check_shard_partition():
    rng = random.Random(7)
    checked = 0
    for grid, rules in [(8, G8), (24, G8), (46, G8), (70, G12), (9, G12)]:
        _, color = build(grid, rules)
        n = grid * grid
        pos = packed_bit_pos(color)
        for clamp_frac in [0.0, 0.3]:
            cmask = [1.0 if rng.random() < clamp_frac else 0.0 for _ in range(n)]
            for c in [0, 1]:
                nodes = [i for i in range(n) if color[i] == c and cmask[i] <= 0.5]
                off = shard_block_bounds(nodes, pos)
                ln = len(nodes)
                # Cover + strict ascent + block-count cap.
                assert off[0] == 0 and off[-1] == ln, (grid, c, off[:3], off[-3:])
                assert all(a < b for a, b in zip(off, off[1:])) or ln == 0
                nb = len(off) - 1
                assert nb <= MAX_SHARD_BLOCKS, f"{nb} blocks > cap"
                # Near-equal: word alignment can defer a cut by at most one
                # word's worth of update-list entries.
                target = max(-(-ln // MAX_SHARD_BLOCKS), 1) if ln else 1
                sizes = [b - a for a, b in zip(off, off[1:])]
                assert all(s <= target + 64 for s in sizes), (target, max(sizes))
                # Word alignment of every interior boundary.
                for j in off[1:-1]:
                    assert pos[nodes[j]] // 64 != pos[nodes[j - 1]] // 64, (
                        f"boundary {j} splits a word"
                    )
                # Word-disjointness across blocks (the packed RMW guarantee).
                words = [
                    {pos[i] // 64 for i in nodes[a:b]} for a, b in zip(off, off[1:])
                ]
                for x in range(len(words)):
                    for y in range(x + 1, len(words)):
                        assert not (words[x] & words[y]), f"blocks {x},{y} share a word"
                # Shard assignment: contiguous, in-order, covers all blocks
                # at every width — the block set itself never depends on S.
                for s_width in list(range(1, 11)) + [nb or 1, 2 * (nb or 1)]:
                    seen = []
                    for shard in range(s_width):
                        mine = [
                            blk
                            for blk in range(nb)
                            if blk * s_width // max(nb, 1) == shard
                        ]
                        assert mine == list(range(mine[0], mine[0] + len(mine))) if mine else True
                        seen.extend(mine)
                    assert seen == list(range(nb)), (s_width, seen[:5])
                checked += 1
    print(f"  shard partition: cover/word-alignment/word-disjointness/"
          f"assignment checked over {checked} (graph, clamp, color) cases")


# ------------------------------------------- 3. sharded toy Gibbs run --


def stream(color, first_node):
    """Deterministic per-(color, block) uniform stream keyed the way
    `shard_block_rngs` keys its forks (by the block's first node id)."""
    state = (color * 0x9E3779B97F4A7C15 + first_node * 0xBF58476D1CE4E5B9 + 1) & (
        (1 << 64) - 1
    )

    def next_uniform():
        nonlocal state
        # splitmix64 step.
        state = (state + 0x9E3779B97F4A7C15) & ((1 << 64) - 1)
        z = state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & ((1 << 64) - 1)
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & ((1 << 64) - 1)
        z ^= z >> 31
        return (z >> 11) / float(1 << 53)

    return next_uniform


def run_block(s, nodes_in_block, nbrs, w, h, beta, draw):
    """Scalar halfsweep restricted to one block: the per-block oracle."""
    for i in nodes_in_block:
        f = h[i]
        for j, v in enumerate(nbrs[i]):
            f = f32(f + f32(w[i][j] * s[v]))
        p = 1.0 / (1.0 + math.exp(-2.0 * beta * f))
        s[i] = 1.0 if draw[i] < p else -1.0


def sharded_run(grid, rules, clamp_frac, s_width, order, seed, sweeps=4):
    """Run the toy machine with blocks grouped into `s_width` shards and
    the shards of each phase executed in `order` ('fwd'|'rev'|'rr')."""
    nbrs, color = build(grid, rules)
    n = grid * grid
    rng = random.Random(seed)
    w = [[f32(rng.uniform(-0.5, 0.5)) for _ in nbrs[i]] for i in range(n)]
    h = [f32(rng.uniform(-0.3, 0.3)) for i in range(n)]
    cmask = [1.0 if rng.random() < clamp_frac else 0.0 for _ in range(n)]
    s = [rng.choice([-1.0, 1.0]) for _ in range(n)]
    pos = packed_bit_pos(color)
    beta = 1.0

    per_color = []
    for c in [0, 1]:
        nodes = [i for i in range(n) if color[i] == c and cmask[i] <= 0.5]
        off = shard_block_bounds(nodes, pos)
        nb = len(off) - 1
        blocks = [nodes[a:b] for a, b in zip(off, off[1:])]
        streams = [stream(c, blk[0]) for blk in blocks]
        per_color.append((blocks, streams, nb))

    for _ in range(sweeps):
        for c in [0, 1]:
            blocks, streams, nb = per_color[c]
            # Pre-draw each block's uniforms from its own stream (the
            # stream advance is per block, independent of shard grouping).
            draws = []
            for blk, st in zip(blocks, streams):
                draws.append({i: st() for i in blk})
            shards = [
                [blk for blk in range(nb) if blk * s_width // max(nb, 1) == sh]
                for sh in range(s_width)
            ]
            if order == "rev":
                shards = shards[::-1]
            if order == "rr":  # round-robin across shards, one block each
                seqd = []
                k = 0
                while any(shards):
                    if shards[k % len(shards)]:
                        seqd.append(shards[k % len(shards)].pop(0))
                    k += 1
                shards = [[blk] for blk in seqd]
            for mine in shards:
                for blk in mine:
                    run_block(s, blocks[blk], nbrs, w, h, beta, draws[blk])
    return s


def check_sharded_gibbs():
    runs = 0
    for grid, rules in [(12, G8), (16, G12)]:
        for clamp_frac in [0.0, 0.25]:
            ref = sharded_run(grid, rules, clamp_frac, 1, "fwd", seed=5)
            for s_width in [2, 3, 5, 64]:
                for order in ["fwd", "rev", "rr"]:
                    got = sharded_run(grid, rules, clamp_frac, s_width, order, seed=5)
                    assert got == ref, (
                        f"grid {grid} clamp {clamp_frac} S={s_width} {order}: "
                        "sharded state != sequential block oracle"
                    )
                    runs += 1
    print(f"  sharded Gibbs: {runs} (width, order) runs bit-identical to the "
          f"sequential per-block oracle, clamped and free")


def main():
    check_padding_algebra()
    check_shard_partition()
    check_sharded_gibbs()
    print("ALL SHARD CHECKS PASSED")


if __name__ == "__main__":
    main()

#!/usr/bin/env python3
"""Algorithm-level verification of the bit-packed spin engine (PR 3).

The dev container has no Rust toolchain, so this ports `gibbs::packed`'s
numeric logic 1:1 to Python (stdlib only) and drives it end to end:

  1. quantizer idempotency + grid detection (`WeightGrid::holds/detect`
     semantics: on-grid values are fixed points, raw Gaussians are not);
  2. packed layout: color-major bit positions, word-aligned color-1
     block, pack/unpack round-trip on random rows (node counts not
     divisible by 64);
  3. masked-popcount local field == direct gather field on random
     quantized machines (the folded -sum(w) constant + 2*w*popcount
     decomposition), to float tolerance;
  4. packed chromatic Gibbs with clamps matches clamped conditional
     marginals from exact enumeration (and clamped bits never move);
  5. a fully-clamped color is a no-op for that color while the other
     color still mixes to the right conditional.

Run: python3 python/tools/verify_packed_sim.py  -> ALL PACKED CHECKS PASSED
"""

import math
import random

# ----------------------------------------------------------------- graph --


def build(grid, rules):
    """graph::build connection structure + checkerboard coloring."""
    n = grid * grid
    nbrs = [[] for _ in range(n)]
    for y in range(grid):
        for x in range(grid):
            u = y * grid + x
            for (a, b) in rules:
                for (dx, dy) in [(a, b), (-b, a), (-a, -b), (b, -a)]:
                    xx, yy = x + dx, y + dy
                    if 0 <= xx < grid and 0 <= yy < grid:
                        nbrs[u].append(yy * grid + xx)
    color = [((i % grid) + (i // grid)) % 2 for i in range(n)]
    return nbrs, color


G8 = [(0, 1), (4, 1)]

# ------------------------------------------------------------- quantizer --


def quantize(v, bits, fs):
    """hw::quantize: midrise ladder, 2^bits levels, rails at +/-fs."""
    v = max(-fs, min(fs, v))
    if bits >= 24:
        return v
    steps = (1 << bits) - 1
    q = round((v + fs) * steps / (2 * fs))
    return q * (2 * fs) / steps - fs


def check_quantizer_and_detection():
    rng = random.Random(0)
    raw = [rng.gauss(0, 0.25) for _ in range(500)]
    for bits in (2, 4, 8, 12):
        q = [quantize(v, bits, 2.0) for v in raw]
        assert all(quantize(v, bits, 2.0) == v for v in q), "not idempotent"
    # detect: raw Gaussians are off every coarse grid; quantized ones hold.
    def holds(ws, bits):
        return all(quantize(w, bits, 2.0) == w for w in ws)

    assert not any(holds(raw, b) for b in range(1, 13)), "raw weights must not qualify"
    q8 = [quantize(v, 8, 2.0) for v in raw]
    assert any(holds(q8, b) for b in range(1, 13)), "8-bit weights must qualify"
    print("1. quantizer idempotent; grid detection separates raw from quantized")


# ---------------------------------------------------------- packed layout --


def layout(color):
    """Color-major bit positions with a word-aligned color-1 block."""
    n = len(color)
    n0 = sum(1 for c in color if c == 0)
    w0 = (n0 + 63) // 64
    pos = [0] * n
    p0, p1 = 0, w0 * 64
    for i in range(n):
        if color[i] == 0:
            pos[i] = p0
            p0 += 1
        else:
            pos[i] = p1
            p1 += 1
    words = w0 + ((n - n0) + 63) // 64
    return pos, words, w0


def pack(pos, words, row):
    ws = [0] * words
    for i, v in enumerate(row):
        if v > 0:
            ws[pos[i] >> 6] |= 1 << (pos[i] & 63)
    return ws


def bit(ws, p):
    return (ws[p >> 6] >> (p & 63)) & 1


def check_layout_roundtrip():
    rng = random.Random(1)
    for grid in (5, 6, 9, 11):  # 25, 36, 81, 121 nodes: none divisible by 64
        nbrs, color = build(grid, G8)
        n = grid * grid
        pos, words, w0 = layout(color)
        n0 = sum(1 for c in color if c == 0)
        assert words == (n0 + 63) // 64 + ((n - n0) + 63) // 64
        row = [rng.choice([-1, 1]) for _ in range(n)]
        ws = pack(pos, words, row)
        for i in range(n):
            assert (1 if bit(ws, pos[i]) else -1) == row[i], "round-trip"
            if color[i] == 0:
                assert pos[i] < w0 * 64
            else:
                assert pos[i] >= w0 * 64, "color-1 block must be word-aligned"
        for i in range(n):
            for j in nbrs[i]:
                assert color[i] != color[j], "graph must be bipartite"
    print("2. packed layout: color-major, word-aligned, round-trips (n % 64 != 0)")


# ------------------------------------------------- popcount field algebra --


def compile_entries(i, nbrs, pos, wt):
    """Per-node merged (word, level, mask) entries + folded bias constant."""
    levels, entries, wsum = [], {}, 0.0
    for j in nbrs[i]:
        w = wt(i, j)
        wsum += w
        if w not in levels:
            levels.append(w)
        key = (pos[j] >> 6, levels.index(w))
        entries[key] = entries.get(key, 0) | (1 << (pos[j] & 63))
    return levels, entries, wsum


def packed_field(h_i, levels, entries, wsum, ws):
    f = h_i - wsum
    for (wd, lv), mask in entries.items():
        f += 2.0 * levels[lv] * bin(ws[wd] & mask).count("1")
    return f


def check_field_algebra():
    rng = random.Random(2)
    for grid in (5, 8):
        nbrs, color = build(grid, G8)
        n = grid * grid
        pos, words, _ = layout(color)
        w = {}
        for u in range(n):
            for v in nbrs[u]:
                if u < v:
                    w[(u, v)] = quantize(rng.gauss(0, 0.25), 8, 2.0)
        h = [rng.gauss(0, 0.2) for _ in range(n)]

        def wt(u, v):
            return w[(min(u, v), max(u, v))]

        row = [rng.choice([-1, 1]) for _ in range(n)]
        ws = pack(pos, words, row)
        worst = 0.0
        for i in range(n):
            direct = h[i] + sum(wt(i, j) * row[j] for j in nbrs[i])
            levels, entries, wsum = compile_entries(i, nbrs, pos, wt)
            worst = max(worst, abs(direct - packed_field(h[i], levels, entries, wsum, ws)))
        assert worst < 1e-9, f"field decomposition error {worst}"
    print("3. masked-popcount field == direct gather field (worst fp error < 1e-9)")


# ------------------------------------------- packed Gibbs vs enumeration --


def exact_marginals(n, wpairs, h, cmask, cval):
    free = [i for i in range(n) if cmask[i] <= 0.5]
    logps = []
    for bits_ in range(1 << len(free)):
        s = [cval[i] if cmask[i] > 0.5 else 0 for i in range(n)]
        for k, i in enumerate(free):
            s[i] = 1 if (bits_ >> k) & 1 else -1
        pair = sum(w * s[u] * s[v] for (u, v), w in wpairs.items())
        field = sum(h[i] * s[i] for i in range(n))
        logps.append((pair + field, s))
    mx = max(lp for lp, _ in logps)
    z, marg = 0.0, [0.0] * n
    for lp, s in logps:
        p = math.exp(lp - mx)
        z += p
        for i in range(n):
            marg[i] += p * s[i]
    return [x / z for x in marg]


def packed_gibbs_marginals(grid, seed, clamp_color=None):
    """Drive the packed engine end to end; return (emp, exact, frozen_ok)."""
    rng = random.Random(seed)
    nbrs, color = build(grid, G8)
    n = grid * grid
    pos, words, _ = layout(color)
    wpairs = {}
    for u in range(n):
        for v in nbrs[u]:
            if u < v:
                wpairs[(u, v)] = quantize(rng.gauss(0, 0.25), 8, 2.0)
    h = [rng.gauss(0, 0.2) for _ in range(n)]

    def wt(u, v):
        return wpairs[(min(u, v), max(u, v))]

    if clamp_color is None:
        data = rng.sample(range(n), 6)
        cmask = [1.0 if i in data else 0.0 for i in range(n)]
    else:
        cmask = [1.0 if color[i] == clamp_color else 0.0 for i in range(n)]
    cval = [rng.choice([-1, 1]) if cmask[i] > 0.5 else 0 for i in range(n)]
    exact = exact_marginals(n, wpairs, h, cmask, cval)

    # Compile per-color update lists exactly like SweepPlanPacked.
    plans = {}
    for c in (0, 1):
        lst = []
        for i in range(n):
            if color[i] != c or cmask[i] > 0.5:
                continue
            levels, entries, wsum = compile_entries(i, nbrs, pos, wt)
            lst.append((i, levels, entries, wsum))
        plans[c] = lst

    B, K, burn = 32, 500, 60
    acc, cnt = [0.0] * n, 0
    for _ in range(B):
        row = [cval[i] if cmask[i] > 0.5 else rng.choice([-1, 1]) for i in range(n)]
        ws = pack(pos, words, row)
        frozen = list(ws)
        for it in range(K):
            for c in (0, 1):
                for (i, levels, entries, wsum) in plans[c]:
                    f = packed_field(h[i], levels, entries, wsum, ws)
                    up = rng.random() < 1.0 / (1.0 + math.exp(-2.0 * f))
                    wd, m = pos[i] >> 6, 1 << (pos[i] & 63)
                    ws[wd] = (ws[wd] | m) if up else (ws[wd] & ~m)
            if it >= burn:
                for i in range(n):
                    acc[i] += 1 if bit(ws, pos[i]) else -1
                cnt += 1
        for i in range(n):
            if cmask[i] > 0.5:
                assert bit(ws, pos[i]) == bit(frozen, pos[i]), "clamped bit moved"
    emp = [a / cnt for a in acc]
    return emp, exact, cmask


def check_gibbs_vs_enumeration():
    emp, exact, cmask = packed_gibbs_marginals(4, seed=3)
    worst = max(abs(e - x) for e, x, m in zip(emp, exact, cmask) if m <= 0.5)
    assert worst < 0.08, f"packed Gibbs vs enumeration worst {worst:.3f}"
    print(f"4. packed Gibbs matches clamped conditional marginals (worst {worst:.4f})")


def check_fully_clamped_color():
    emp, exact, cmask = packed_gibbs_marginals(4, seed=5, clamp_color=0)
    worst = max(abs(e - x) for e, x, m in zip(emp, exact, cmask) if m <= 0.5)
    assert worst < 0.08, f"fully-clamped-color conditional worst {worst:.3f}"
    print(f"5. fully-clamped color is a frozen no-op; free color mixes (worst {worst:.4f})")


if __name__ == "__main__":
    check_quantizer_and_detection()
    check_layout_roundtrip()
    check_field_algebra()
    check_gibbs_vs_enumeration()
    check_fully_clamped_color()
    print("ALL PACKED CHECKS PASSED")

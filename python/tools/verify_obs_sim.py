#!/usr/bin/env python3
"""Algorithm-level verification of the obs:: histogram (rust/src/obs/hist.rs).

The dev image has no Rust toolchain, so this re-simulates the
log-bucketed histogram bit-for-bit from the IEEE-754 bit pattern — the
same `(bits >> 52) & 0x7ff` exponent extraction and top-3-mantissa-bit
sub-bucketing the Rust `bucket_index` performs — and property-tests the
documented contracts:

  * golden bucket indices (1.0 -> 257, range edges, NaN/0/negative/
    subnormal -> underflow, inf/huge -> overflow);
  * bucket bounds are contiguous, contain their values, and are monotone;
  * every in-range bucket midpoint is within REL_ERROR_BOUND = 1/16 of
    any value in the bucket (the analytic (hi-lo)/(2*lo) bound);
  * quantile(q) is within REL_ERROR_BOUND of the exact sorted[rank-1]
    for in-range data, across distributions and q values;
  * merge is element-wise, associative, and matches recording the union.

Stdlib only. Exit code is the gate; prints ALL OBS CHECKS PASSED.
"""

import math
import random
import struct

SUB_BUCKETS_LOG2 = 3
SUB_BUCKETS = 1 << SUB_BUCKETS_LOG2
EXP_MIN = -32
EXP_MAX = 32
N_BUCKETS = 2 + (EXP_MAX - EXP_MIN) * SUB_BUCKETS
REL_ERROR_BOUND = 1.0 / 16.0


def f64_bits(v):
    return struct.unpack("<Q", struct.pack("<d", v))[0]


def bucket_index(v):
    """Mirror of rust/src/obs/hist.rs bucket_index, bit for bit."""
    if math.isnan(v) or v <= 0.0:
        return 0
    bits = f64_bits(v)
    exp = ((bits >> 52) & 0x7FF) - 1023
    if exp < EXP_MIN:
        return 0
    if exp >= EXP_MAX:
        return N_BUCKETS - 1
    sub = (bits >> (52 - SUB_BUCKETS_LOG2)) & (SUB_BUCKETS - 1)
    return 1 + (exp - EXP_MIN) * SUB_BUCKETS + sub


def bucket_bounds(idx):
    assert 0 <= idx < N_BUCKETS
    if idx == 0:
        return (0.0, 2.0**EXP_MIN)
    if idx == N_BUCKETS - 1:
        return (2.0**EXP_MAX, math.inf)
    i = idx - 1
    base = 2.0 ** (EXP_MIN + i // SUB_BUCKETS)
    s = i % SUB_BUCKETS
    return (base * (1.0 + s / SUB_BUCKETS), base * (1.0 + (s + 1) / SUB_BUCKETS))


def bucket_mid(idx):
    lo, hi = bucket_bounds(idx)
    if idx == 0:
        return 0.0
    if idx == N_BUCKETS - 1:
        return lo
    return 0.5 * (lo + hi)


def record(buckets, v):
    buckets[bucket_index(v)] += 1


def quantile(buckets, q):
    """Mirror of HistData::quantile."""
    count = sum(buckets)
    if count == 0:
        return 0.0
    q = min(max(q, 0.0), 1.0)
    rank = min(max(int(math.ceil(q * count)), 1), count)
    seen = 0
    for i, c in enumerate(buckets):
        seen += c
        if seen >= rank:
            return bucket_mid(i)
    return bucket_mid(N_BUCKETS - 1)


def check(cond, msg):
    if not cond:
        raise SystemExit(f"OBS CHECK FAILED: {msg}")


def check_golden_indices():
    check(N_BUCKETS == 514, f"N_BUCKETS = {N_BUCKETS}, want 514")
    check(bucket_index(1.0) == 257, f"bucket_index(1.0) = {bucket_index(1.0)}")
    check(bucket_index(1.9999) == 264, "1.9999 must land in the last sub-bucket of octave 0")
    check(bucket_index(2.0) == 265, "2.0 must open octave 1")
    for v in (0.0, -3.0, math.nan, 1e-300, 2.0 ** (EXP_MIN - 1)):
        check(bucket_index(v) == 0, f"{v!r} must underflow")
    for v in (math.inf, 1e300, 2.0**EXP_MAX):
        check(bucket_index(v) == N_BUCKETS - 1, f"{v!r} must overflow")
    check(bucket_index(2.0**EXP_MIN) == 1, "2^EXP_MIN opens bucket 1")
    # Sub-bucket edges are exact: 2^e * (1 + s/8) opens sub-bucket s.
    for s in range(SUB_BUCKETS):
        v = 4.0 * (1.0 + s / SUB_BUCKETS)
        want = 1 + (2 - EXP_MIN) * SUB_BUCKETS + s
        check(bucket_index(v) == want, f"edge {v}: got {bucket_index(v)}, want {want}")
    print("golden bucket indices: ok")


def check_bounds_and_monotonicity(rng):
    for idx in range(N_BUCKETS - 1):
        hi = bucket_bounds(idx)[1]
        lo2 = bucket_bounds(idx + 1)[0]
        check(hi == lo2, f"gap between buckets {idx} and {idx + 1}")
    vals = sorted(
        2.0 ** (rng.uniform(-40.0, 40.0)) * (1.0 + rng.random()) for _ in range(4000)
    )
    prev = -1
    for v in vals:
        idx = bucket_index(v)
        check(idx >= prev, f"bucket_index not monotone at v={v}")
        prev = idx
        lo, hi = bucket_bounds(idx)
        if 0 < idx < N_BUCKETS - 1:
            check(lo <= v < hi, f"v={v} outside its bucket [{lo},{hi})")
    print("bounds containment + contiguity + monotonicity: ok")


def check_midpoint_bound():
    # The documented worst case: |mid - v| / v <= (hi - lo) / (2 lo)
    # <= 1/(2*(SUB_BUCKETS + s)) <= 1/16, for every in-range bucket.
    worst = 0.0
    for idx in range(1, N_BUCKETS - 1):
        lo, hi = bucket_bounds(idx)
        worst = max(worst, (hi - lo) / (2.0 * lo))
    check(worst <= REL_ERROR_BOUND + 1e-15, f"analytic midpoint bound {worst} > 1/16")
    check(worst > REL_ERROR_BOUND - 1e-3, "bound should be tight near 1/16")
    print(f"analytic midpoint error bound: ok (worst {worst:.6f} <= 1/16)")


def check_quantiles(rng):
    distributions = {
        "lognormal-latency": lambda: 2.0 ** rng.uniform(-2.0, 10.0) * (1.0 + rng.random()),
        "uniform-narrow": lambda: 1.0 + rng.random(),
        "heavy-tail": lambda: rng.paretovariate(1.5),
        "exponential": lambda: rng.expovariate(0.2) + 1e-6,
    }
    for name, draw in distributions.items():
        vals = [draw() for _ in range(5000)]
        buckets = [0] * N_BUCKETS
        for v in vals:
            record(buckets, v)
        check(sum(buckets) == len(vals), f"{name}: lost observations")
        exact = sorted(vals)
        for q in (0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999, 1.0):
            rank = min(max(int(math.ceil(q * len(vals))), 1), len(vals))
            want = exact[rank - 1]
            got = quantile(buckets, q)
            if bucket_index(want) in (0, N_BUCKETS - 1):
                continue  # bound only documented for in-range values
            rel = abs(got - want) / want
            check(
                rel <= REL_ERROR_BOUND + 1e-12,
                f"{name} q={q}: got {got}, exact {want}, rel {rel}",
            )
    print("quantile error bound across distributions: ok")


def check_merge(rng):
    def mk(n):
        b = [0] * N_BUCKETS
        for _ in range(n):
            record(b, 2.0 ** rng.uniform(-10.0, 10.0))
        return b

    a, b, c = mk(300), mk(500), mk(700)
    add = lambda x, y: [p + q for p, q in zip(x, y)]
    check(add(add(a, b), c) == add(a, add(b, c)), "merge must be associative")
    check(add(a, b) == add(b, a), "merge must be commutative")
    union = add(a, b)
    check(sum(union) == sum(a) + sum(b), "merged count must equal union")
    # Quantiles of the merge agree with re-recording the union's buckets.
    for q in (0.1, 0.5, 0.9):
        check(
            quantile(union, q) == quantile(add(b, a), q),
            "merge order must not change quantiles",
        )
    print("merge associativity/commutativity/union: ok")


def main():
    rng = random.Random(0xD7CA)
    check_golden_indices()
    check_bounds_and_monotonicity(rng)
    check_midpoint_bound()
    check_quantiles(rng)
    check_merge(rng)
    print("ALL OBS CHECKS PASSED")


if __name__ == "__main__":
    main()

#!/usr/bin/env python3
"""Algorithm-level verification of the bit-sliced chain-major engine (PR 8).

The dev container has no Rust toolchain, so this ports `gibbs::bitsliced`'s
numeric logic 1:1 to Python (stdlib only) and drives it end to end:

  1. chain-major transpose: one int per NODE, bit c = chain slice_base+c;
     round-trips random batches, including partial slices (B % 64 != 0)
     with dummy lanes initialized down and masked out;
  2. the logistic inverse-CDF threshold table: LOGIT_TAB[r] =
     logit((r+0.5)/2^16) is monotone, and the amortized update rule
     `tab[r] < z` reproduces P(flip) = sigmoid(z) to the 2^-16 uniform
     quantization bound (the per-update bias the Rust engine accepts in
     exchange for dropping exp() from the hot loop), saturating
     deterministically past the table rails;
  3. lane-broadcast field algebra: folded bias (h_i - sum_v w_v) plus
     pre-doubled per-level accumulation over neighbor chain-words equals
     the direct gather field in every lane, to float tolerance;
  4. fused statistics identities on random slice states: per-slot pair
     sums via live-masked XOR popcount (sum_lanes s_i*s_j = live -
     2*popcount((w_i ^ w_j) & live_mask)) and per-lane node means via
     up-counts (mean = 2*cnt - kept) both match direct accumulation,
     exactly, for full and partial slices;
  5. bit-sliced chromatic Gibbs with clamps — threshold-table updates,
     all lanes of a node advanced per step — matches clamped conditional
     marginals from exact enumeration (and clamped lane bits never move).

Run: python3 python/tools/verify_bitsliced_sim.py -> ALL BITSLICED CHECKS PASSED
"""

import math
import random

LANES = 64
LANE_MASK = (1 << LANES) - 1

# ----------------------------------------------------------------- graph --


def build(grid, rules):
    """graph::build connection structure + checkerboard coloring."""
    n = grid * grid
    nbrs = [[] for _ in range(n)]
    for y in range(grid):
        for x in range(grid):
            u = y * grid + x
            for (a, b) in rules:
                for (dx, dy) in [(a, b), (-b, a), (-a, -b), (b, -a)]:
                    xx, yy = x + dx, y + dy
                    if 0 <= xx < grid and 0 <= yy < grid:
                        nbrs[u].append(yy * grid + xx)
    color = [((i % grid) + (i // grid)) % 2 for i in range(n)]
    return nbrs, color


G8 = [(0, 1), (4, 1)]


def quantize(v, bits, fs):
    """hw::quantize: midrise ladder, 2^bits levels, rails at +/-fs."""
    v = max(-fs, min(fs, v))
    if bits >= 24:
        return v
    steps = (1 << bits) - 1
    q = round((v + fs) * steps / (2 * fs))
    return q * (2 * fs) / steps - fs


# ------------------------------------------------- chain-major transpose --


def from_chains(rows, slice_base, live, n):
    """BitslicedState::from_chains: words[i] bit c = chain sb+c node i up."""
    words = [0] * n
    for c in range(live):
        row = rows[slice_base + c]
        for i in range(n):
            if row[i] > 0:
                words[i] |= 1 << c
    return words


def write_chains(words, rows, slice_base, live, n):
    for c in range(live):
        rows[slice_base + c] = [1 if (words[i] >> c) & 1 else -1 for i in range(n)]


def check_transpose_roundtrip():
    rng = random.Random(7)
    n = 25
    for b in (3, 64, 70, 128, 130):
        rows = [[rng.choice([-1, 1]) for _ in range(n)] for _ in range(b)]
        slices = (b + LANES - 1) // LANES
        back = [None] * b
        for si in range(slices):
            live = b - si * LANES if si == slices - 1 else LANES
            words = from_chains(rows, si * LANES, live, n)
            # Dummy lanes (>= live) must be zero-initialized (down).
            for i in range(n):
                assert words[i] >> live == 0, "dummy lanes must init down"
            write_chains(words, back, si * LANES, live, n)
        assert back == rows, f"B={b}: chain-major transpose must round-trip"
    print("1. chain-major transpose round-trips (full and partial slices)")


# ------------------------------------------------------- threshold table --


def logit_table():
    return [math.log(u / (1.0 - u)) for u in ((r + 0.5) / 65536.0 for r in range(1 << 16))]


def check_threshold_table():
    tab = logit_table()
    assert all(tab[r] <= tab[r + 1] for r in range(len(tab) - 1)), "monotone"
    for z in (-8.0, -3.0, -0.5, 0.0, 0.31, 2.7, 6.0):
        p = sum(1 for t in tab if t < z) / 65536.0
        sig = 1.0 / (1.0 + math.exp(-z))
        assert abs(p - sig) <= 1.0 / 65536.0 + 1e-12, f"z={z}: {p} vs {sig}"
    # Rails: the table spans +/- logit(1/2^17) ~= +/-11.78; any field past
    # them flips deterministically (strong-bias freeze semantics).
    rail = math.log(131071.0)
    assert -rail - 1e-9 < tab[0] and tab[-1] < rail + 1e-9
    assert all(t < 12.0 for t in tab) and all(t > -12.0 for t in tab)
    print("2. threshold table inverts sigmoid to 2^-16 (rails at +/-11.78)")


# ------------------------------------------------- lane field + stats ----


def compile_node(i, nbrs, wt, h):
    """SweepPlanBitsliced per-node entry: folded bias + (nbr, 2w) list."""
    wsum = sum(wt(i, j) for j in nbrs[i])
    return h[i] - wsum, [(j, 2.0 * wt(i, j)) for j in nbrs[i]]


def lane_fields(bias, entries, words, live):
    """The lane-broadcast accumulation the Rust half() performs."""
    f = [bias] * live
    for (j, w2) in entries:
        wj = words[j]
        for c in range(live):
            f[c] += w2 * ((wj >> c) & 1)
    return f


def check_field_algebra():
    rng = random.Random(2)
    for grid in (5, 8):
        nbrs, _ = build(grid, G8)
        n = grid * grid
        w = {}
        for u in range(n):
            for v in nbrs[u]:
                if u < v:
                    w[(u, v)] = quantize(rng.gauss(0, 0.25), 8, 2.0)
        h = [rng.gauss(0, 0.2) for _ in range(n)]

        def wt(u, v):
            return w[(min(u, v), max(u, v))]

        live = 64
        rows = [[rng.choice([-1, 1]) for _ in range(n)] for _ in range(live)]
        words = from_chains(rows, 0, live, n)
        worst = 0.0
        for i in range(n):
            bias, entries = compile_node(i, nbrs, wt, h)
            fl = lane_fields(bias, entries, words, live)
            for c in range(live):
                direct = h[i] + sum(wt(i, j) * rows[c][j] for j in nbrs[i])
                worst = max(worst, abs(direct - fl[c]))
        assert worst < 1e-9, f"lane field decomposition error {worst}"
    print("3. lane-broadcast field == direct gather field in every lane (< 1e-9)")


def popcount(x):
    return bin(x).count("1")


def check_stats_identities():
    rng = random.Random(11)
    n = 30
    kept = 5
    for live in (64, 6):
        live_mask = (1 << live) - 1
        pair_xor = 0
        pair_direct = 0
        up = [[0] * live for _ in range(n)]
        mean_direct = [[0] * live for _ in range(n)]
        i, j = 4, 17
        for _ in range(kept):
            words = [rng.getrandbits(LANES) & live_mask for _ in range(n)]
            # Pair: XOR identity on one (i, j) slot.
            pair_xor += live - 2 * popcount((words[i] ^ words[j]) & live_mask)
            for c in range(live):
                si = 1 if (words[i] >> c) & 1 else -1
                sj = 1 if (words[j] >> c) & 1 else -1
                pair_direct += si * sj
            # Mean: up-count identity per (node, lane).
            for k in range(n):
                for c in range(live):
                    b = (words[k] >> c) & 1
                    up[k][c] += b
                    mean_direct[k][c] += 2 * b - 1
        assert pair_xor == pair_direct, "XOR pair identity must be exact"
        for k in range(n):
            for c in range(live):
                assert 2 * up[k][c] - kept == mean_direct[k][c], "mean identity"
    print("4. XOR pair sums and up-count means match direct accumulation exactly")


# ------------------------------------------- bitsliced Gibbs vs exact ----


def exact_marginals(n, wpairs, h, cmask, cval):
    free = [i for i in range(n) if cmask[i] <= 0.5]
    logps = []
    for bits_ in range(1 << len(free)):
        s = [cval[i] if cmask[i] > 0.5 else 0 for i in range(n)]
        for k, i in enumerate(free):
            s[i] = 1 if (bits_ >> k) & 1 else -1
        pair = sum(w * s[u] * s[v] for (u, v), w in wpairs.items())
        field = sum(h[i] * s[i] for i in range(n))
        logps.append((pair + field, s))
    mx = max(lp for lp, _ in logps)
    z, marg = 0.0, [0.0] * n
    for lp, s in logps:
        p = math.exp(lp - mx)
        z += p
        for i in range(n):
            marg[i] += p * s[i]
    return [x / z for x in marg]


def check_gibbs_vs_enumeration():
    rng = random.Random(3)
    grid = 4
    nbrs, color = build(grid, G8)
    n = grid * grid
    wpairs = {}
    for u in range(n):
        for v in nbrs[u]:
            if u < v:
                wpairs[(u, v)] = quantize(rng.gauss(0, 0.25), 8, 2.0)
    h = [rng.gauss(0, 0.2) for _ in range(n)]

    def wt(u, v):
        return wpairs[(min(u, v), max(u, v))]

    data = rng.sample(range(n), 6)
    cmask = [1.0 if i in data else 0.0 for i in range(n)]
    cval = [rng.choice([-1, 1]) if cmask[i] > 0.5 else 0 for i in range(n)]
    exact = exact_marginals(n, wpairs, h, cmask, cval)

    # Compile per-color (node, folded bias, entries) lists like the Rust
    # plan; run one 64-lane slice plus a partial 6-lane slice (B = 70).
    plans = {}
    for c in (0, 1):
        plans[c] = [
            (i,) + compile_node(i, nbrs, wt, h)
            for i in range(n)
            if color[i] == c and cmask[i] <= 0.5
        ]

    tab = logit_table()
    K, burn = 500, 60
    acc, cnt = [0.0] * n, 0
    for live in (64, 6):
        rows = [
            [cval[i] if cmask[i] > 0.5 else rng.choice([-1, 1]) for i in range(n)]
            for _ in range(live)
        ]
        words = from_chains(rows, 0, live, n)
        frozen = list(words)
        live_mask = (1 << live) - 1
        clamp_bits = [1 if cmask[i] > 0.5 else 0 for i in range(n)]
        for it in range(K):
            for c in (0, 1):
                for (i, bias, entries) in plans[c]:
                    f = lane_fields(bias, entries, words, live)
                    # One 16-bit draw per lane; flip iff tab[r] < 2*beta*f,
                    # the exp-free amortized Bernoulli of the Rust engine.
                    w_new = 0
                    for lane in range(live):
                        r = rng.getrandbits(16)
                        if tab[r] < 2.0 * f[lane]:
                            w_new |= 1 << lane
                    words[i] = w_new
            if it >= burn:
                for i in range(n):
                    # Up-count fold: sum of lane spins = 2*popcount - live.
                    acc[i] += 2 * popcount(words[i] & live_mask) - live
                cnt += live
        for i in range(n):
            if clamp_bits[i]:
                assert words[i] == frozen[i], "clamped lanes moved"
    emp = [a / cnt for a in acc]
    worst = max(abs(e - x) for e, x, m in zip(emp, exact, cmask) if m <= 0.5)
    assert worst < 0.08, f"bitsliced Gibbs vs enumeration worst {worst:.3f}"
    print(f"5. bitsliced Gibbs matches clamped conditional marginals (worst {worst:.4f})")


if __name__ == "__main__":
    check_transpose_roundtrip()
    check_threshold_table()
    check_field_algebra()
    check_stats_identities()
    check_gibbs_vs_enumeration()
    print("ALL BITSLICED CHECKS PASSED")

#!/usr/bin/env python3
"""Bench regression gate: compare freshly emitted BENCH_*.json files against
committed baselines and FAIL on throughput regression.

Usage:
    python3 python/tools/check_bench.py [--threshold 0.25] [--update] \
        FRESH=BASELINE [FRESH=BASELINE ...]

e.g. (what CI runs after the bench smokes):
    python3 python/tools/check_bench.py \
        BENCH_gibbs.json=baselines/BENCH_gibbs.json \
        BENCH_hw.json=baselines/BENCH_hw.json

Rules (stdlib only, exit code is the gate):
  * rows are matched by their "name" field inside "configs";
  * every numeric field ending in `_per_sec` or `_per_joule` is compared; a
    fresh value below baseline * (1 - threshold) is a REGRESSION -> exit 1;
  * every numeric field ending in `_ns` is a latency: the gate is reversed,
    a fresh value above baseline * (1 + threshold) fails;
  * a baseline value of null means "seeded, not yet measured" (the repo is
    bootstrapped from a toolchain-less image): reported, never failing —
    run with --update on a quiet machine and commit the result to arm the
    gate for that row. Non-null baseline values are two-tier: hand-written
    conservative floors/ceilings (documented in the baseline's "note") arm
    catastrophic-regression detection on any host; a measured --update
    refresh tightens them to real numbers;
  * a baseline row may carry "min_ratio_vs": [{"row": R, "field": F,
    "min": M}, ...] — each entry asserts the FRESH value of this row's F is
    >= M * the FRESH value of row R's F (cross-row ratio gates, e.g.
    "sharding must not collapse throughput vs the S=1 row"); these compare
    fresh against fresh, so they bite even while the absolute baselines are
    still hand-written floors;
  * a baseline row missing from the fresh output is a FAILURE (renaming or
    dropping a bench must be done deliberately, by updating the baseline);
  * new fresh rows/fields simply report "new (no baseline)";
  * --update rewrites each baseline from the fresh file (all gated fields
    filled in), so refreshing baselines is one command; the top-level
    "note" and each row's "min_ratio_vs"/"note" are curated gate config and
    survive the rewrite.

A table is printed either way so the numbers land in the CI log.
"""

import argparse
import json
import os
import sys

THRESHOLD_DEFAULT = 0.25


def load(path):
    with open(path) as f:
        return json.load(f)


def rows_by_name(doc):
    out = {}
    for row in doc.get("configs", []):
        name = row.get("name")
        if isinstance(name, str):
            out[name] = row
    return out


def perf_fields(row):
    return sorted(
        k
        for k, v in row.items()
        if k.endswith(("_per_sec", "_per_joule", "_ns"))
        and (v is None or isinstance(v, (int, float)))
    )


def is_latency(field):
    """Latency fields gate in reverse: bigger fresh values are regressions."""
    return field.endswith("_ns")


def fmt(v):
    if v is None:
        return "-"
    if isinstance(v, float) and (v != v):  # NaN
        return "nan"
    return f"{v:,.1f}"


def check_pair(fresh_path, base_path, threshold, update):
    print(f"\n== {fresh_path} vs {base_path} ==")
    if not os.path.exists(fresh_path):
        print(f"FAIL: fresh bench output {fresh_path!r} missing (bench did not run?)")
        return ["missing fresh output"]
    fresh = rows_by_name(load(fresh_path))
    if not os.path.exists(base_path):
        print(f"note: no baseline at {base_path!r}; nothing to gate against")
        if update:
            write_baseline(fresh_path, base_path)
        return []
    base = rows_by_name(load(base_path))

    failures = []
    header = f"{'row':<28} {'field':<26} {'baseline':>14} {'fresh':>14} {'ratio':>7}  status"
    print(header)
    print("-" * len(header))
    for name, brow in sorted(base.items()):
        frow = fresh.get(name)
        if frow is None:
            print(f"{name:<28} {'-':<26} {'-':>14} {'-':>14} {'-':>7}  MISSING from fresh run")
            failures.append(f"{name}: row missing from fresh output")
            continue
        for field in perf_fields(brow):
            bval = brow.get(field)
            fval = frow.get(field)
            if bval is None:
                status = "seeded (no measured baseline yet)"
                ratio = "-"
            elif not isinstance(fval, (int, float)):
                status = "MISSING field in fresh row"
                failures.append(f"{name}.{field}: missing from fresh output")
                ratio = "-"
            else:
                ratio = f"{fval / bval:5.2f}x" if bval > 0 else "-"
                if is_latency(field):
                    if bval > 0 and fval > bval * (1.0 + threshold):
                        status = f"REGRESSION (> {threshold:.0%} above baseline latency)"
                        failures.append(
                            f"{name}.{field}: {fval:,.1f} > {bval * (1 + threshold):,.1f} "
                            f"(baseline {bval:,.1f})"
                        )
                    else:
                        status = "ok"
                elif bval > 0 and fval < bval * (1.0 - threshold):
                    status = f"REGRESSION (> {threshold:.0%} below baseline)"
                    failures.append(
                        f"{name}.{field}: {fval:,.1f} < {bval * (1 - threshold):,.1f} "
                        f"(baseline {bval:,.1f})"
                    )
                else:
                    status = "ok"
            print(f"{name:<28} {field:<26} {fmt(bval):>14} {fmt(fval):>14} {ratio:>7}  {status}")
        for cons in brow.get("min_ratio_vs", []):
            ref_name, field = cons.get("row"), cons.get("field")
            m = cons.get("min")
            fval = frow.get(field)
            rval = fresh.get(ref_name, {}).get(field)
            label = f"{field} >= {m}x {ref_name}"
            if not isinstance(fval, (int, float)) or not isinstance(rval, (int, float)):
                status = "MISSING fresh value(s) for ratio gate"
                failures.append(f"{name}: min_ratio_vs {label}: value(s) missing")
                ratio = "-"
            else:
                ratio = f"{fval / rval:5.2f}x" if rval > 0 else "-"
                if rval > 0 and fval < m * rval:
                    status = f"RATIO REGRESSION (< {m}x of {ref_name})"
                    failures.append(
                        f"{name}: min_ratio_vs {label}: {fval:,.1f} < "
                        f"{m * rval:,.1f} ({ref_name}.{field} = {rval:,.1f})"
                    )
                else:
                    status = "ok"
            print(
                f"{name:<28} {('ratio:' + field)[:26]:<26} {fmt(rval):>14} {fmt(fval):>14} "
                f"{ratio:>7}  {status}"
            )
    for name in sorted(set(fresh) - set(base)):
        print(f"{name:<28} {'*':<26} {'-':>14} {'-':>14} {'-':>7}  new (no baseline)")

    if update:
        write_baseline(fresh_path, base_path)
    return failures


def write_baseline(fresh_path, base_path):
    os.makedirs(os.path.dirname(base_path) or ".", exist_ok=True)
    with open(fresh_path) as f:
        doc = json.load(f)
    # Curated gate config survives the rewrite: fresh bench output never
    # carries the policy note or the cross-row ratio constraints, so pull
    # them forward from the old baseline.
    if os.path.exists(base_path):
        old = load(base_path)
        if "note" in old:
            doc["note"] = old["note"]
        old_rows = rows_by_name(old)
        for row in doc.get("configs", []):
            orow = old_rows.get(row.get("name"))
            if orow:
                for key in ("min_ratio_vs", "note"):
                    if key in orow and key not in row:
                        row[key] = orow[key]
    with open(base_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"updated baseline {base_path} from {fresh_path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("pairs", nargs="+", metavar="FRESH=BASELINE")
    ap.add_argument("--threshold", type=float, default=THRESHOLD_DEFAULT,
                    help="max tolerated fractional drop (default 0.25)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite each baseline from the fresh file")
    args = ap.parse_args()

    all_failures = []
    for pair in args.pairs:
        if "=" not in pair:
            ap.error(f"expected FRESH=BASELINE, got {pair!r}")
        fresh_path, base_path = pair.split("=", 1)
        all_failures += check_pair(fresh_path, base_path, args.threshold, args.update)

    print()
    if all_failures:
        print(f"BENCH GATE FAILED ({len(all_failures)} problem(s)):")
        for f in all_failures:
            print(f"  - {f}")
        sys.exit(1)
    print("BENCH GATE PASSED")


if __name__ == "__main__":
    main()

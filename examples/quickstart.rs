//! Quickstart: open the AOT artifacts, validate the HLO Gibbs hot path
//! against exact enumeration, train a small DTM for a few epochs, generate
//! images, and report quality + the device-model energy cost.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).
//!
//! No flags — this is the smallest full tour of the stack. For knobs, see
//! `e2e_train` (training) and `serve_demo` (serving).
//!
//! Expected output: the PJRT platform banner, the dtm_m32 topology line,
//! three epochs of grad norms, a proxy-FID score, an energy summary, and
//! a closing `quickstart OK`.

use anyhow::Result;

use thermo_dtm::coordinator::pipeline::generate_images;
use thermo_dtm::data::{fashion_dataset, FashionConfig};
use thermo_dtm::energy::{self, DeviceParams};
use thermo_dtm::metrics::{self, FeatureNet};
use thermo_dtm::model::Dtm;
use thermo_dtm::runtime::Runtime;
use thermo_dtm::train::acp::AcpParams;
use thermo_dtm::train::sampler::HloSampler;
use thermo_dtm::train::trainer::{TrainConfig, Trainer};
use thermo_dtm::util::rng::Rng;

fn main() -> Result<()> {
    // 1) Open the artifact set produced by `make artifacts`.
    let rt = Runtime::open(Runtime::default_dir())?;
    println!("PJRT platform: {} | {} DTM configs", rt.platform(), rt.manifest.dtm.len());

    // 2) Bind the workhorse config: L=32 G12 grid, 256 data nodes.
    let exec = rt.dtm_exec("dtm_m32")?;
    let top = exec.top.clone();
    println!(
        "dtm_m32: {} nodes, {} edges, degree {} — chromatic Gibbs via Pallas/HLO",
        top.n_nodes(),
        top.n_edges(),
        top.degree
    );
    let sampler = HloSampler::new(exec, 7);

    // 3) Train a 2-step DTM briefly on the synthetic fashion dataset.
    let ds = fashion_dataset(&FashionConfig::default(), 300, 3);
    let dtm = Dtm::init("dtm_m32", &top, 2, 3.0, 1);
    let cfg = TrainConfig {
        epochs: 3,
        batches_per_epoch: 2,
        k_train: 20,
        burn: 7,
        lr: 0.03,
        acp: Some(AcpParams::default()),
        eval_every: 0,
        k_eval: 40,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(sampler, dtm, cfg, ds.images.clone())?;
    tr.run(&ds.images)?;
    println!("trained 3 epochs; grad norms: {:?}",
        tr.log.iter().map(|r| (r.grad_norm * 1e3).round() / 1e3).collect::<Vec<_>>());

    // 4) Generate and score.
    let mut rng = Rng::new(9);
    let imgs = generate_images(&mut tr.sampler, &tr.dtm, 40, 96, &mut rng)?;
    let feat = FeatureNet::new(256, 0xF1D);
    let pfid = metrics::pfid(&feat, &ds.images, ds.n, &imgs, 96)?;
    println!("proxy-FID after quick training: {pfid:.2}");

    // 5) Energy accounting (App. E device model).
    let pe = energy::denoising_energy(&DeviceParams::default(), "G12", 32, 256, 2, 40)?;
    println!(
        "DTCA energy model: {:.2} nJ/sample; GPU VAE baseline (App. F): {:.2} µJ/sample",
        pe.total * 1e9,
        energy::gpu::energy_per_sample(7.0e4) * 1e6
    );

    // 6) Render one sample.
    for r in 0..16 {
        let line: String = (0..16)
            .map(|c| if imgs[r * 16 + c] > 0.0 { '#' } else { '.' })
            .collect();
        println!("  {line}");
    }
    println!("quickstart OK");
    Ok(())
}

//! Serving demo: run the dynamic-batching server over the HLO hot path and
//! report latency/throughput under concurrent load — the "serving paper"
//! face of the L3 coordinator.
//!
//! Run: `cargo run --release --example serve_demo` (after `make artifacts`;
//! without artifacts it falls back to a freshly built topology).
//!
//! No flags — batching and load are fixed in the source (device_batch 32,
//! T=4, K=30). For the fault-tolerant multi-chip farm with deadlines,
//! retries and fault injection, use `repro serve --chips N` instead.
//!
//! Expected output: a banner with the config, throughput in images/s, and
//! latency p50/p99 in milliseconds.

use std::time::{Duration, Instant};

use anyhow::Result;

use thermo_dtm::coordinator::batcher::BatcherConfig;
use thermo_dtm::coordinator::{Server, ServerConfig};
use thermo_dtm::graph;
use thermo_dtm::model::Dtm;
use thermo_dtm::runtime::Runtime;
use thermo_dtm::train::sampler::HloSampler;

fn main() -> Result<()> {
    let cfg_name = "dtm_m32";
    // An untrained model is fine for a serving benchmark: the compute is
    // identical (T chained K-iteration Gibbs programs per batch).
    let top = match Runtime::open(Runtime::default_dir()) {
        Ok(rt) => rt.topology(cfg_name)?,
        Err(_) => graph::build(cfg_name, 32, "G12", 256, 7)?,
    };
    let dtm = Dtm::init(cfg_name, &top, 4, 3.0, 1);

    let cfg = ServerConfig {
        batcher: BatcherConfig {
            device_batch: 32,
            linger: Duration::from_millis(5),
            max_queue: 4096,
        },
        k_inference: 30,
        seed: 4,
    };
    let server = Server::spawn(cfg, dtm, move || {
        let rt = Runtime::open(Runtime::default_dir())?;
        Ok(HloSampler::new(rt.dtm_exec(cfg_name)?, 13))
    });
    let client = server.client();

    // Offered load: 48 concurrent requests of mixed sizes.
    let sizes = [1usize, 2, 4, 8, 16];
    let t0 = Instant::now();
    let waiters: Vec<_> = (0..48)
        .map(|i| client.generate_async(sizes[i % sizes.len()]).unwrap())
        .collect();
    let mut total_images = 0usize;
    for w in waiters {
        // Every submission resolves to Ok(Response) or a typed ServeError.
        let resp = w.recv()??;
        total_images += resp.images.len() / top.data_nodes.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    println!("== serve_demo (HLO hot path, T=4, K=30) ==");
    println!(
        "{} requests / {total_images} images in {wall:.2}s -> {:.1} img/s",
        stats.requests,
        total_images as f64 / wall
    );
    println!(
        "dispatched {} device batches, mean fill {:.2}",
        stats.batches,
        stats.mean_fill()
    );
    println!("latency p50 {:.1} ms  p99 {:.1} ms", stats.p50_ms(), stats.p99_ms());
    Ok(())
}

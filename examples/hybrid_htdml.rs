//! Hybrid thermodynamic–deterministic demo (paper Sec. V / Fig. 6):
//! autoencoder embeds synthetic color images into a 64-bit binary latent
//! space; a DTM models the latents; the decoder maps DTM samples back.
//!
//! Run: `cargo run --release --example hybrid_htdml [-- --fast]`.
//!
//! Flags to vary: `--fast` shrinks the run for smoke-testing; the shared
//! figure flags (`--out DIR`, `--seed N`, `--repr`, `--threads`) apply
//! too, since this drives the same harness as `repro figures fig6`.
//!
//! Expected output: progress lines from the Fig. 6 harness and a
//! `fig6*.csv` table under the output directory (default `results/`).

use anyhow::Result;

use thermo_dtm::figures::{frontier, FigOpts};
use thermo_dtm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let opts = FigOpts::from_args(&args)?;
    std::fs::create_dir_all(&opts.out_dir)?;
    frontier::fig6(&opts)
}

//! Hybrid thermodynamic–deterministic demo (paper Sec. V / Fig. 6):
//! autoencoder embeds synthetic color images into a 64-bit binary latent
//! space; a DTM models the latents; the decoder maps DTM samples back.
//!
//! Run: `cargo run --release --example hybrid_htdml [-- --fast]`.

use anyhow::Result;

use thermo_dtm::figures::{frontier, FigOpts};
use thermo_dtm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let opts = FigOpts::from_args(&args)?;
    std::fs::create_dir_all(&opts.out_dir)?;
    frontier::fig6(&opts)
}

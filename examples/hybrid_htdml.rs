//! Hybrid thermodynamic–deterministic demo (paper Sec. V / Fig. 6):
//! autoencoder embeds synthetic color images into a 64-bit binary latent
//! space; a DTM models the latents; the decoder maps DTM samples back.
//!
//! Run: `cargo run --release --example hybrid_htdml [-- --fast]`.

use anyhow::Result;

use thermo_dtm::figures::{frontier, FigOpts};
use thermo_dtm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let opts = FigOpts {
        out_dir: args.str_opt("out", "results"),
        fast: args.bool_flag("fast"),
        artifacts: args.str_opt("artifacts", "artifacts"),
        seed: args.usize_opt("seed", 0)? as u64,
    };
    std::fs::create_dir_all(&opts.out_dir)?;
    frontier::fig6(&opts)
}

//! END-TO-END VALIDATION (see ARCHITECTURE.md for the stack layout):
//! train a DTM through the full three-layer stack — Rust coordinator →
//! PJRT-executed HLO (L2 JAX programs wrapping the L1 Pallas Gibbs kernel) —
//! on the synthetic fashion workload, for a few hundred gradient steps,
//! logging the quality curve (proxy-FID), the per-layer mixing observable
//! r_yy[K], ACP penalties, and finally the paper's headline energy
//! comparison for the trained model.
//!
//! Run: `cargo run --release --example e2e_train [-- --epochs N]`
//!
//! Flags to vary: `--epochs N` (default 12) and `--t-steps`/`--k-train`
//! trade training time against quality; `--backend rust` swaps the HLO
//! hot path for the pure-Rust engine so the example runs without
//! `make artifacts`.
//!
//! Expected output: per-epoch lines with proxy-FID, mean r_yy[K] and ACP
//! state, then a final device-vs-GPU energy summary for the trained model.

use anyhow::Result;

use thermo_dtm::data::{fashion_dataset, FashionConfig};
use thermo_dtm::energy::{self, DeviceParams};
use thermo_dtm::graph;
use thermo_dtm::model::Dtm;
use thermo_dtm::runtime::Runtime;
use thermo_dtm::train::acp::AcpParams;
use thermo_dtm::train::sampler::{HloSampler, LayerSampler, RustSampler};
use thermo_dtm::train::trainer::{TrainConfig, Trainer};
use thermo_dtm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let epochs = args.usize_opt("epochs", 12)?;
    let t_steps = args.usize_opt("t-steps", 4)?;
    let k_train = args.usize_opt("k-train", 30)?;
    let backend = args.str_opt("backend", "hlo");
    let cfg_name = "dtm_m32";

    let sampler: Box<dyn LayerSampler> = if backend == "hlo" {
        let rt = Runtime::open(Runtime::default_dir())?;
        println!("backend: HLO via PJRT ({})", rt.platform());
        Box::new(HloSampler::new(rt.dtm_exec(cfg_name)?, 7))
    } else {
        println!("backend: pure-Rust Gibbs");
        Box::new(RustSampler::new(graph::build(cfg_name, 32, "G12", 256, 7)?, 32, 7))
    };
    let top = sampler.topology().clone();

    let ds = fashion_dataset(&FashionConfig::default(), 400, 3);
    let dtm = Dtm::init(cfg_name, &top, t_steps, 3.0, 1);
    println!(
        "model: T={t_steps} layers x ({} nodes, {} edges) = {} parameters",
        top.n_nodes(),
        top.n_edges(),
        dtm.n_params()
    );

    let cfg = TrainConfig {
        epochs,
        batches_per_epoch: 4,
        k_train,
        burn: k_train / 3,
        lr: 0.02,
        acp: Some(AcpParams::default()),
        fixed_lambda: 0.0,
        eval_every: 2,
        eval_samples: 128,
        k_eval: 2 * k_train,
        seed: 0,
    };
    // Gradient steps = epochs * batches * T layers.
    println!(
        "training: {} gradient steps ({} epochs x 4 batches x {} layers), K_train={}",
        epochs * 4 * t_steps,
        epochs,
        t_steps,
        k_train
    );
    let t0 = std::time::Instant::now();
    let mut tr = Trainer::new(sampler, dtm, cfg, ds.images.clone())?;
    tr.run(&ds.images)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nepoch  grad_norm  max_ryy  max_lambda   pfid");
    for r in &tr.log {
        println!(
            "{:>5}  {:>9.4}  {:>7.3}  {:>10.5}  {}",
            r.epoch,
            r.grad_norm,
            r.ryy.iter().cloned().fold(0.0, f64::max),
            r.lambdas.iter().cloned().fold(0.0, f64::max),
            r.pfid.map(|x| format!("{x:.3}")).unwrap_or_else(|| "-".into())
        );
    }
    let first = tr.log.iter().find_map(|r| r.pfid);
    let last = tr.final_pfid();
    println!("\nwall clock: {wall:.1}s");
    if let (Some(a), Some(b)) = (first, last) {
        let verdict = if b < a { "improved" } else { "no improvement" };
        println!("proxy-FID: {a:.2} -> {b:.2} ({verdict})");
    }

    // Paper headline accounting for this trained model.
    let k_inf = 2 * k_train;
    let pe = energy::denoising_energy(&DeviceParams::default(), "G12", 32, 256, t_steps, k_inf)?;
    let gpu_vae = energy::gpu::energy_per_sample(7.0e4);
    println!(
        "energy: DTCA {:.3e} J/sample vs GPU-VAE {:.3e} J/sample -> {:.0}x",
        pe.total,
        gpu_vae,
        gpu_vae / pe.total
    );
    tr.dtm.save(std::path::Path::new("results/e2e_dtm.json"))?;
    println!("checkpoint: results/e2e_dtm.json");
    Ok(())
}
